"""L1 kernel vs ref oracle — the CORE correctness signal.

Hypothesis sweeps shapes/seeds; fixed cases pin the paper-relevant shapes.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    clenshaw,
    ds_gradient,
    ds_gradient_u8,
    nearest_levels,
    stochastic_levels,
    stochastic_quantize,
)
from compile.kernels import ref
from compile.kernels.ds_grad import dequantize_u8

SETTINGS = dict(max_examples=8, deadline=None, derandomize=True)


def _rng(seed):
    return np.random.default_rng(seed)


@st.composite
def shape_seed(draw, max_rows=96, max_cols=160):
    rows = draw(st.integers(1, max_rows))
    cols = draw(st.integers(1, max_cols))
    seed = draw(st.integers(0, 2**31 - 1))
    return rows, cols, seed


@given(shape_seed(), st.integers(1, 255))
@settings(**SETTINGS)
def test_stochastic_quantize_matches_ref(sh, s):
    rows, cols, seed = sh
    rng = _rng(seed)
    v = rng.normal(size=(rows, cols)).astype(np.float32) * 3.0
    r = rng.random(size=(rows, cols)).astype(np.float32)
    m = (np.abs(v).max(axis=0, keepdims=True) + 1e-3).astype(np.float32)
    sv = np.array([[float(s)]], dtype=np.float32)
    out = np.asarray(stochastic_quantize(jnp.array(v), jnp.array(r), jnp.array(m), jnp.array(sv)))
    exp = np.asarray(ref.stochastic_quantize_ref(jnp.array(v), jnp.array(r), jnp.array(m), jnp.array(sv)))
    np.testing.assert_allclose(out, exp, atol=1e-6)


@given(shape_seed(max_rows=48, max_cols=96), st.integers(2, 33))
@settings(**SETTINGS)
def test_stochastic_levels_matches_ref(sh, nlevels):
    rows, cols, seed = sh
    rng = _rng(seed)
    v = rng.normal(size=(rows, cols)).astype(np.float32)
    r = rng.random(size=(rows, cols)).astype(np.float32)
    lv = np.sort(rng.normal(size=nlevels)).astype(np.float32)
    lv = np.unique(lv)
    if lv.size < 2:
        lv = np.array([-1.0, 1.0], dtype=np.float32)
    out = np.asarray(stochastic_levels(jnp.array(v), jnp.array(r), jnp.array(lv)))
    exp = np.asarray(ref.stochastic_levels_ref(jnp.array(v), jnp.array(r), jnp.array(lv)))
    np.testing.assert_allclose(out, exp, atol=1e-6)
    # outputs land exactly on grid points
    assert np.isin(out.ravel().round(6), lv.round(6)).all()


@given(shape_seed(max_rows=48, max_cols=96), st.integers(2, 17))
@settings(**SETTINGS)
def test_nearest_levels_matches_ref(sh, nlevels):
    rows, cols, seed = sh
    rng = _rng(seed)
    v = rng.normal(size=(rows, cols)).astype(np.float32)
    lv = np.unique(np.sort(rng.normal(size=nlevels)).astype(np.float32))
    if lv.size < 2:
        lv = np.array([-1.0, 1.0], dtype=np.float32)
    out = np.asarray(nearest_levels(jnp.array(v), jnp.array(lv)))
    exp = np.asarray(ref.nearest_levels_ref(jnp.array(v), jnp.array(lv)))
    np.testing.assert_allclose(out, exp, atol=1e-6)


def test_quantizer_statistically_unbiased():
    """E[Q(v)] = v — the ZipML linchpin (Lemma 6, unbiasedness).

    Trials are stacked on the row axis so a single kernel call covers all of
    them (each row gets independent randomness).
    """
    rng = _rng(7)
    trials, n = 8192, 64
    v_row = rng.uniform(-1, 1, size=(1, n)).astype(np.float32)
    v = np.broadcast_to(v_row, (trials, n)).copy()
    r = rng.random(size=(trials, n)).astype(np.float32)
    m = np.ones((1, n), dtype=np.float32)
    s = np.array([[3.0]], dtype=np.float32)
    out = np.asarray(stochastic_quantize(jnp.array(v), jnp.array(r), jnp.array(m), jnp.array(s)))
    err = np.abs(out.mean(axis=0) - v_row.ravel()).max()
    # per-sample std ≤ (1/s) / 2 = 1/6; mean std ≈ 0.00184; max of 64 coords
    # stays within ~4 sigma ≈ 0.0074 whp; assert at 6 sigma.
    assert err < 0.011, err


@given(st.integers(1, 96), st.integers(1, 160), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_ds_gradient_matches_ref(batch, n, seed):
    rng = _rng(seed)
    a1 = rng.normal(size=(batch, n)).astype(np.float32)
    a2 = rng.normal(size=(batch, n)).astype(np.float32)
    x = rng.normal(size=(n, 1)).astype(np.float32)
    b = rng.normal(size=(batch, 1)).astype(np.float32)
    g = np.asarray(ds_gradient(jnp.array(a1), jnp.array(a2), jnp.array(x), jnp.array(b)))
    ge = np.asarray(ref.ds_gradient_ref(jnp.array(a1), jnp.array(a2), jnp.array(x), jnp.array(b)))
    np.testing.assert_allclose(g, ge, atol=1e-4 * max(1.0, np.abs(ge).max()))


@given(st.integers(1, 64), st.integers(1, 128), st.integers(1, 255), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_dequantize_u8_matches_ref(batch, n, s, seed):
    rng = _rng(seed)
    idx = rng.integers(0, s + 1, size=(batch, n)).astype(np.uint8)
    m = rng.uniform(0.1, 4.0, size=(1, n)).astype(np.float32)
    sv = np.array([[float(s)]], dtype=np.float32)
    out = np.asarray(dequantize_u8(jnp.array(idx), jnp.array(m), jnp.array(sv)))
    exp = np.asarray(ref.dequantize_u8_ref(jnp.array(idx), jnp.array(m), jnp.array(sv)))
    np.testing.assert_allclose(out, exp, rtol=1e-6)


def test_ds_gradient_u8_matches_ref():
    rng = _rng(3)
    batch, n, s = 64, 100, 15
    i1 = rng.integers(0, s + 1, size=(batch, n)).astype(np.uint8)
    i2 = rng.integers(0, s + 1, size=(batch, n)).astype(np.uint8)
    m = rng.uniform(0.5, 2.0, size=(1, n)).astype(np.float32)
    sv = np.array([[float(s)]], dtype=np.float32)
    x = rng.normal(size=(n, 1)).astype(np.float32)
    b = rng.normal(size=(batch, 1)).astype(np.float32)
    g = np.asarray(ds_gradient_u8(jnp.array(i1), jnp.array(i2), jnp.array(m), jnp.array(sv), jnp.array(x), jnp.array(b)))
    ge = np.asarray(ref.ds_gradient_u8_ref(jnp.array(i1), jnp.array(i2), jnp.array(m), jnp.array(sv), jnp.array(x), jnp.array(b)))
    np.testing.assert_allclose(g, ge, atol=1e-3)


def _np_stochastic_quantize(v, rand, m, s):
    """Vectorized numpy twin of the quantizer (kernel==ref already tested)."""
    u = np.clip(v / m, -1.0, 1.0)
    t = (u + 1.0) * 0.5 * s
    lo = np.clip(np.floor(t), 0.0, s - 1.0)
    idx = lo + (rand < (t - lo))
    return (idx / s * 2.0 - 1.0) * m


def test_ds_gradient_unbiased_for_full_gradient():
    """E over quantizations of the DS gradient == full-precision gradient.

    Statistical property of the estimator itself, so it runs on the numpy
    twin (kernel equality to ref is covered above) with trials vectorized.
    """
    rng = _rng(11)
    batch, n, s, trials = 16, 20, 3.0, 6000
    a = rng.normal(size=(batch, n)).astype(np.float64)
    x = rng.normal(size=(n, 1)).astype(np.float64)
    b = rng.normal(size=(batch, 1)).astype(np.float64)
    m = np.abs(a).max(axis=0, keepdims=True) + 1e-3
    gfull = a.T @ (a @ x - b) / batch
    r1 = rng.random(size=(trials, batch, n))
    r2 = rng.random(size=(trials, batch, n))
    q1 = _np_stochastic_quantize(a[None], r1, m[None], s)
    q2 = _np_stochastic_quantize(a[None], r2, m[None], s)
    res1 = q1 @ x - b[None]
    res2 = q2 @ x - b[None]
    g = (np.einsum("tbn,tbo->tno", q1, res2) + np.einsum("tbn,tbo->tno", q2, res1)) * (0.5 / batch)
    err = np.abs(g.mean(axis=0) - gfull).max()
    assert err < 0.06, err  # ≈5 sigma for this (s, trials)


@given(st.integers(1, 200), st.integers(0, 15), st.integers(0, 2**31 - 1),
       st.floats(1.0, 16.0))
@settings(**SETTINGS)
def test_clenshaw_matches_cos_form(batch, deg, seed, radius):
    rng = _rng(seed)
    z = (rng.normal(size=(batch, 1)) * radius).astype(np.float32)
    coefs = rng.normal(size=(deg + 1, 1)).astype(np.float32)
    out = np.asarray(clenshaw(jnp.array(z), jnp.array(coefs), radius)).ravel()
    exp = ref.clenshaw_ref(z, coefs, radius).ravel()
    scale = max(1.0, np.abs(exp).max())
    np.testing.assert_allclose(out, exp, atol=2e-4 * scale)
