"""L2 model semantics: each step function does the math it claims.

These run the *jitted jax functions* (the exact computations that get
lowered to the artifacts), so passing here + HLO-text round-trip in the Rust
integration tests covers the full compile path.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model


def _mk(seed, batch=32, n=20):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(batch, n)).astype(np.float32)
    xstar = rng.normal(size=(n, 1)).astype(np.float32)
    b = (a @ xstar + 0.01 * rng.normal(size=(batch, 1))).astype(np.float32)
    x0 = np.zeros((n, 1), dtype=np.float32)
    return rng, a, b, x0, xstar


def test_linreg_fp_step_math():
    _, a, b, x0, _ = _mk(0)
    lr = np.array([[0.05]], dtype=np.float32)
    (x1,) = model.linreg_fp_step(jnp.array(x0), jnp.array(a), jnp.array(b), jnp.array(lr))
    g = a.T @ (a @ x0 - b) / a.shape[0]
    np.testing.assert_allclose(np.asarray(x1), x0 - 0.05 * g, atol=1e-5)


def test_linreg_fp_converges():
    _, a, b, x0, xstar = _mk(1, batch=64, n=10)
    lr = jnp.array([[0.05]], dtype=jnp.float32)
    x = jnp.array(x0)
    step = jax.jit(model.linreg_fp_step)
    for _ in range(800):
        (x,) = step(x, jnp.array(a), jnp.array(b), lr)
    assert np.abs(np.asarray(x) - xstar).max() < 0.05


def test_linreg_ds_step_equals_fp_when_unquantized():
    """With a1 == a2 == a the DS estimator reduces to the exact gradient."""
    _, a, b, x0, _ = _mk(2)
    lr = jnp.array([[0.1]], dtype=jnp.float32)
    x0j = jnp.array(x0)
    (x_fp,) = model.linreg_fp_step(x0j, jnp.array(a), jnp.array(b), lr)
    (x_ds,) = model.linreg_ds_step(x0j, jnp.array(a), jnp.array(a), jnp.array(b), lr)
    np.testing.assert_allclose(np.asarray(x_fp), np.asarray(x_ds), atol=1e-5)


def test_lssvm_step_includes_regularizer():
    _, a, b, x0, _ = _mk(3)
    x0 = x0 + 1.0
    lr = np.array([[0.1]], dtype=np.float32)
    c = np.array([[0.5]], dtype=np.float32)
    (x1,) = model.lssvm_ds_step(jnp.array(x0), jnp.array(a), jnp.array(a), jnp.array(b), jnp.array(lr), jnp.array(c))
    g = a.T @ (a @ x0 - b) / a.shape[0] + 0.5 * x0
    np.testing.assert_allclose(np.asarray(x1), x0 - 0.1 * g, atol=1e-4)


def test_e2e_step_shapes_and_finite():
    rng, a, b, x0, _ = _mk(4, batch=32, n=20)
    n = 20
    lr = jnp.array([[0.05]], dtype=jnp.float32)
    out, = model.e2e_step(
        jnp.array(x0 + 0.3), jnp.array(a), jnp.array(a), jnp.array(b), lr,
        jnp.array(rng.random((1, n), dtype=np.float32)),
        jnp.array(rng.random((1, n), dtype=np.float32)),
        jnp.array([[15.0]], dtype=jnp.float32), jnp.array([[127.0]], dtype=jnp.float32))
    assert out.shape == (n, 1) and np.isfinite(np.asarray(out)).all()


def test_e2e_step_unbiased_update():
    """E[e2e update] == fp update direction (model+gradient quantizers unbiased)."""
    rng, a, b, x0, _ = _mk(5, batch=16, n=10)
    n = 10
    x = (x0 + 0.5).astype(np.float32)
    lr = jnp.array([[1.0]], dtype=jnp.float32)
    g_fp = a.T @ (a @ x - b) / a.shape[0]
    acc = np.zeros_like(x)
    trials = 1200
    fn = jax.jit(model.e2e_step)
    for _ in range(trials):
        (x1,) = fn(jnp.array(x), jnp.array(a), jnp.array(a), jnp.array(b), lr,
                   jnp.array(rng.random((1, n), dtype=np.float32)),
                   jnp.array(rng.random((1, n), dtype=np.float32)),
                   jnp.array([[63.0]], dtype=jnp.float32),
                   jnp.array([[255.0]], dtype=jnp.float32))
        acc += x - np.asarray(x1)  # = lr * gq
    mean_update = acc / trials
    err = np.abs(mean_update - g_fp).max()
    assert err < 0.05 * max(1.0, np.abs(g_fp).max()), err


def test_logistic_fp_step_reduces_loss():
    rng = np.random.default_rng(6)
    batch, n = 64, 12
    a = rng.normal(size=(batch, n)).astype(np.float32)
    w = rng.normal(size=(n, 1)).astype(np.float32)
    b = np.sign(a @ w).astype(np.float32)
    x = jnp.zeros((n, 1), jnp.float32)
    lr = jnp.array([[0.5]], dtype=jnp.float32)
    (l0,) = model.logistic_loss(x, jnp.array(a), jnp.array(b))
    step = jax.jit(model.logistic_fp_step)
    for _ in range(200):
        (x,) = step(x, jnp.array(a), jnp.array(b), lr)
    (l1,) = model.logistic_loss(x, jnp.array(a), jnp.array(b))
    assert float(l1[0, 0]) < 0.5 * float(l0[0, 0])


def test_svm_fp_step_subgradient():
    rng = np.random.default_rng(7)
    batch, n = 16, 8
    a = rng.normal(size=(batch, n)).astype(np.float32)
    b = np.sign(rng.normal(size=(batch, 1))).astype(np.float32)
    x = rng.normal(size=(n, 1)).astype(np.float32)
    lr = np.array([[0.1]], dtype=np.float32)
    (x1,) = model.svm_fp_step(jnp.array(x), jnp.array(a), jnp.array(b), jnp.array(lr))
    z = b * (a @ x)
    g = -(a.T @ (b * (z < 1))) / batch
    np.testing.assert_allclose(np.asarray(x1), x - 0.1 * g, atol=1e-5)


def test_poly_ds_step_matches_direct_poly_eval():
    """With all quantizations equal to a, poly step == direct P(z) gradient."""
    rng = np.random.default_rng(8)
    batch, n, deg = 16, 10, 15
    a = rng.normal(size=(batch, n)).astype(np.float32) * 0.3
    b = np.sign(rng.normal(size=(batch, 1))).astype(np.float32)
    x = rng.normal(size=(n, 1)).astype(np.float32) * 0.3
    mono = (rng.normal(size=(deg + 1, 1)) * 0.2).astype(np.float32)
    lr = np.array([[1.0]], dtype=np.float32)
    aq = np.broadcast_to(a, (deg + 1, batch, n)).astype(np.float32)
    (x1,) = model.poly_ds_step(jnp.array(x), jnp.array(aq), jnp.array(b), jnp.array(lr), jnp.array(mono))
    z = (b * (a @ x)).ravel().astype(np.float64)
    pval = np.polyval(mono.ravel()[::-1].astype(np.float64), z)
    g = a.T @ (b.ravel() * pval).reshape(-1, 1) / batch
    np.testing.assert_allclose(np.asarray(x - x1), g, atol=5e-4)


def test_cheby_step_approximates_logistic_gradient():
    """Chebyshev ℓ' approx drives the same descent direction as exact σ."""
    from numpy.polynomial import chebyshev as C
    rng = np.random.default_rng(9)
    batch, n = 64, 12
    a = (rng.normal(size=(batch, n)) * 0.2).astype(np.float32)
    w = rng.normal(size=(n, 1)).astype(np.float32)
    b = np.sign(a @ w).astype(np.float32)
    R = model.RADIUS
    # interpolate ℓ'(z) = -sigmoid(-z) on [-R, R] at Chebyshev nodes, deg 15
    nodes = np.cos((2 * np.arange(16) + 1) / 32 * np.pi) * R
    vals = -1.0 / (1.0 + np.exp(nodes))
    coefs = C.chebfit(nodes / R, vals, 15).astype(np.float32).reshape(-1, 1)
    x = jnp.zeros((n, 1), jnp.float32)
    lr = jnp.array([[0.5]], dtype=jnp.float32)
    stepc = jax.jit(model.cheby_step)
    for _ in range(150):
        (x,) = stepc(x, jnp.array(a), jnp.array(a), jnp.array(b), lr, jnp.array(coefs))
    (l1,) = model.logistic_loss(x, jnp.array(a), jnp.array(b))
    assert float(l1[0, 0]) < 0.6  # down from log(2) ≈ 0.693 at x=0


def _mlp_params(rng):
    d0, d1, d2, d3 = model.MLP_DIMS
    scale = lambda fan: np.sqrt(2.0 / fan)
    return (
        (rng.normal(size=(d0, d1)) * scale(d0)).astype(np.float32),
        np.zeros((1, d1), np.float32),
        (rng.normal(size=(d1, d2)) * scale(d1)).astype(np.float32),
        np.zeros((1, d2), np.float32),
        (rng.normal(size=(d2, d3)) * scale(d2)).astype(np.float32),
        np.zeros((1, d3), np.float32),
    )


def test_mlp_fp_step_reduces_loss():
    rng = np.random.default_rng(10)
    params = tuple(jnp.array(p) for p in _mlp_params(rng))
    x = jnp.array(rng.normal(size=(64, 784)).astype(np.float32))
    y = jnp.array(rng.integers(0, 10, size=(64,)).astype(np.int32))
    lr = jnp.array([[0.1]], dtype=jnp.float32)
    step = jax.jit(model.mlp_fp_step)
    out = step(*params, x, y, lr)
    loss0 = float(out[6][0, 0])
    for _ in range(30):
        out = step(*out[:6], x, y, lr)
    assert float(out[6][0, 0]) < loss0 * 0.5


def test_mlp_q_step_quantized_forward_and_descends():
    rng = np.random.default_rng(11)
    params = tuple(jnp.array(p) for p in _mlp_params(rng))
    x = jnp.array(rng.normal(size=(64, 784)).astype(np.float32))
    y = jnp.array(rng.integers(0, 10, size=(64,)).astype(np.int32))
    lr = jnp.array([[0.1]], dtype=jnp.float32)
    lv = jnp.array(np.linspace(-0.3, 0.3, 33).astype(np.float32))
    step = jax.jit(model.mlp_q_step)
    out = step(*params, x, y, lr, lv, lv, lv)
    loss0 = float(out[6][0, 0])
    for _ in range(40):
        out = step(*out[:6], x, y, lr, lv, lv, lv)
    assert float(out[6][0, 0]) < loss0 * 0.8
    # quantized eval uses only grid weights: check eval_q runs and is finite
    l, acc = model.mlp_eval_q(*out[:6], x, y, lv, lv, lv)
    assert np.isfinite(float(l[0, 0])) and 0.0 <= float(acc[0, 0]) <= 1.0


def test_epoch_step_matches_sequential_steps():
    rng = np.random.default_rng(12)
    nb, batch, n = 8, 16, 10
    a = rng.normal(size=(nb, batch, n)).astype(np.float32)
    b = rng.normal(size=(nb, batch, 1)).astype(np.float32)
    x = np.zeros((n, 1), np.float32)
    lr = jnp.array([[0.05]], dtype=jnp.float32)
    (x_epoch,) = model.linreg_fp_epoch(jnp.array(x), jnp.array(a), jnp.array(b), lr)
    xs = jnp.array(x)
    for i in range(nb):
        (xs,) = model.linreg_fp_step(xs, jnp.array(a[i]), jnp.array(b[i]), lr)
    np.testing.assert_allclose(np.asarray(x_epoch), np.asarray(xs), atol=1e-5)
