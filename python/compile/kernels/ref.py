"""Pure-jnp correctness oracles for every L1 Pallas kernel.

These are the ground truth the kernels are tested against (pytest +
hypothesis); they are also what the L2 model *could* use directly — the
kernels must be drop-in replacements up to float tolerance.
"""
import jax.numpy as jnp
import numpy as np


def stochastic_quantize_ref(v, rand, m, s):
    """Uniform symmetric stochastic quantizer, E[out] = clip(v, -m, m)."""
    s = jnp.asarray(s).reshape(())
    safe_m = jnp.where(m > 0.0, m, 1.0)
    u = jnp.clip(v / safe_m, -1.0, 1.0)
    t = (u + 1.0) * 0.5 * s
    lo = jnp.clip(jnp.floor(t), 0.0, s - 1.0)
    p = t - lo
    idx = lo + (rand < p).astype(v.dtype)
    q = (idx / s * 2.0 - 1.0) * m
    return jnp.where(m > 0.0, q, 0.0)


def stochastic_levels_ref(v, rand, levels):
    """Stochastic rounding onto an arbitrary sorted grid ``levels`` (L,)."""
    cmp = (v[..., None] > levels[None, None, :]).astype(jnp.float32)
    idx = jnp.clip(jnp.sum(cmp, axis=-1), 1.0, levels.shape[0] - 1.0).astype(jnp.int32)
    lo = levels[idx - 1]
    hi = levels[idx]
    vc = jnp.clip(v, levels[0], levels[-1])
    width = hi - lo
    p = jnp.where(width > 0.0, (vc - lo) / jnp.where(width > 0, width, 1.0), 0.0)
    return jnp.where(rand < p, hi, lo)


def nearest_levels_ref(v, levels):
    """Deterministic nearest-level assignment."""
    cmp = (v[..., None] > levels[None, None, :]).astype(jnp.float32)
    idx = jnp.clip(jnp.sum(cmp, axis=-1), 1.0, levels.shape[0] - 1.0).astype(jnp.int32)
    lo = levels[idx - 1]
    hi = levels[idx]
    vc = jnp.clip(v, levels[0], levels[-1])
    return jnp.where(vc - lo <= hi - vc, lo, hi)


def ds_gradient_ref(a1, a2, x, b):
    """Symmetrized double-sampling least-squares gradient (n, 1)."""
    batch = a1.shape[0]
    r1 = a1 @ x - b
    r2 = a2 @ x - b
    return (a1.T @ r2 + a2.T @ r1) * (0.5 / batch)


def dequantize_u8_ref(idx, m, s):
    s = jnp.asarray(s).reshape(())
    return (idx.astype(jnp.float32) / s * 2.0 - 1.0) * m


def ds_gradient_u8_ref(idx1, idx2, m, s, x, b):
    a1 = dequantize_u8_ref(idx1, m, s)
    a2 = dequantize_u8_ref(idx2, m, s)
    return ds_gradient_ref(a1, a2, x, b)


def clenshaw_ref(z, coefs, radius):
    """Direct T_k summation (numpy cos-acos form) as oracle for Clenshaw."""
    t = np.clip(np.asarray(z, dtype=np.float64) / radius, -1.0, 1.0)
    coefs = np.asarray(coefs, dtype=np.float64).reshape(-1)
    theta = np.arccos(t)
    out = np.zeros_like(t)
    for k, c in enumerate(coefs):
        out += c * np.cos(k * theta)
    return out
