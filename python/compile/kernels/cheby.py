"""Chebyshev / Clenshaw evaluation Pallas kernel (ZipML §4.2).

Evaluates P(z) = Σ_k c_k T_k(z / R) at a batch of scalars via the Clenshaw
recurrence (numerically stable, unlike monomial expansion, for the degree-15
approximations the paper uses for the sigmoid and the Heaviside step).
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_B_TILE = 128


def _clenshaw_kernel(z_ref, coef_ref, o_ref, *, radius: float):
    t = jnp.clip(z_ref[...] / radius, -1.0, 1.0)
    coefs = coef_ref[...]  # (D+1, 1)
    deg = coefs.shape[0] - 1

    def body(k, carry):
        bk1, bk2 = carry
        # descending index: j = deg - k
        c = jax.lax.dynamic_slice_in_dim(coefs, deg - k, 1, axis=0)[0, 0]
        bk = c + 2.0 * t * bk1 - bk2
        return (bk, bk1)

    zeros = jnp.zeros_like(t)
    b1, b2 = jax.lax.fori_loop(0, deg, body, (zeros, zeros))
    c0 = coefs[0, 0]
    o_ref[...] = c0 + t * b1 - b2


def clenshaw(z, coefs, radius):
    """P(z) with Chebyshev coefficients ``coefs`` (D+1, 1) on [-radius, radius].

    z: (B, 1). Out-of-range z is clamped (the paper constrains ‖x‖₂ ≤ R so
    |aᵀx| ≤ R for normalized samples).
    """
    rows = z.shape[0]
    bt = next(c for c in range(min(rows, _B_TILE), 0, -1) if rows % c == 0)
    ncoef = coefs.shape[0]
    return pl.pallas_call(
        functools.partial(_clenshaw_kernel, radius=float(radius)),
        grid=(pl.cdiv(rows, bt),),
        in_specs=[
            pl.BlockSpec((bt, 1), lambda i: (i, 0)),
            pl.BlockSpec((ncoef, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(z.shape, z.dtype),
        interpret=True,
    )(z, coefs)
