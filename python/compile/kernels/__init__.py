"""L1 Pallas kernels for ZipML (interpret=True — CPU-PJRT runnable HLO).

Each kernel has a pure-jnp oracle in `ref.py`; pytest asserts allclose.
"""
from .quantize import stochastic_quantize, nearest_levels, stochastic_levels
from .ds_grad import ds_gradient, ds_gradient_u8
from .cheby import clenshaw

__all__ = [
    "stochastic_quantize",
    "nearest_levels",
    "stochastic_levels",
    "ds_gradient",
    "ds_gradient_u8",
    "clenshaw",
]
