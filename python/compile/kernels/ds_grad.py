"""Double-sampling gradient Pallas kernels (ZipML §2.2 / §B.2).

The unbiased low-precision least-squares gradient over a minibatch of two
independent quantizations ``A1, A2`` of the same samples is the symmetrized
estimator the paper uses in practice (footnote 2):

    g = 1/(2B) * [ A1ᵀ(A2 x − b) + A2ᵀ(A1 x − b) ]

Two kernels, composed over a 2-D grid (the HBM↔VMEM schedule of DESIGN.md
§4):

* `_residual_kernel` — r = A x − b, tiled (batch × feature) with feature-
  axis accumulation into the output block (revisited across the inner grid
  dimension, the standard Pallas accumulation idiom).
* `_grad_kernel`     — g_tile = A1[:, tile]ᵀ r2 + A2[:, tile]ᵀ r1, tiled
  (feature × batch) with batch-axis accumulation.

`ds_gradient_u8` is the bandwidth-faithful variant: samples arrive as u8
level indices plus per-column scales and are dequantized *inside* the kernel
(HBM traffic is 1 byte/value instead of 4 — the paper's FPGA argument mapped
to the TPU memory hierarchy).
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_B_TILE = 32
_F_TILE = 128


def _tile(dim: int, tile: int) -> int:
    """Largest divisor of ``dim`` that is ≤ ``tile``.

    Partial tiles are padded (with NaN under interpret mode) and would
    poison the matmul accumulations, so blocks must divide exactly.
    """
    for cand in range(min(tile, dim), 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def _residual_kernel(a_ref, x_ref, b_ref, r_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        r_ref[...] = -b_ref[...]

    r_ref[...] += a_ref[...] @ x_ref[...]


def _residual(a, x, b):
    """r = a @ x - b with x (n,1), b (B,1)."""
    rows, cols = a.shape
    bt, ft = _tile(rows, _B_TILE), _tile(cols, _F_TILE)
    grid = (pl.cdiv(rows, bt), pl.cdiv(cols, ft))
    return pl.pallas_call(
        _residual_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, ft), lambda i, j: (i, j)),
            pl.BlockSpec((ft, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 1), a.dtype),
        interpret=True,
    )(a, x, b)


def _grad_kernel(a1_ref, a2_ref, r1_ref, r2_ref, g_ref, *, inv2b: float):
    i = pl.program_id(1)  # batch tile (inner, accumulated)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    contrib = a1_ref[...].T @ r2_ref[...] + a2_ref[...].T @ r1_ref[...]
    g_ref[...] += contrib * inv2b


def _grad(a1, a2, r1, r2):
    rows, cols = a1.shape
    bt, ft = _tile(rows, _B_TILE), _tile(cols, _F_TILE)
    grid = (pl.cdiv(cols, ft), pl.cdiv(rows, bt))
    return pl.pallas_call(
        functools.partial(_grad_kernel, inv2b=0.5 / rows),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, ft), lambda j, i: (i, j)),
            pl.BlockSpec((bt, ft), lambda j, i: (i, j)),
            pl.BlockSpec((bt, 1), lambda j, i: (i, 0)),
            pl.BlockSpec((bt, 1), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ft, 1), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((cols, 1), a1.dtype),
        interpret=True,
    )(a1, a2, r1, r2)


def ds_gradient(a1, a2, x, b):
    """Symmetrized double-sampling least-squares gradient.

    a1, a2: (B, n) independent quantizations; x: (n, 1); b: (B, 1).
    Returns g: (n, 1), an unbiased estimator of ∇ 1/(2B)Σ(aᵀx − b)².
    """
    r1 = _residual(a1, x, b)
    r2 = _residual(a2, x, b)
    return _grad(a1, a2, r1, r2)


def _dequant_kernel(idx_ref, m_ref, s_ref, o_ref):
    """u8 level index → f32 value on the symmetric uniform grid."""
    idx = idx_ref[...].astype(jnp.float32)
    m = m_ref[...]
    s = s_ref[0, 0]
    o_ref[...] = (idx / s * 2.0 - 1.0) * m


def dequantize_u8(idx, m, s):
    """Dequantize u8 indices (R, C) with per-column scale m (1, C), s intervals."""
    rows, cols = idx.shape
    rt, ct = _tile(rows, _B_TILE), _tile(cols, _F_TILE)
    grid = (pl.cdiv(rows, rt), pl.cdiv(cols, ct))
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rt, ct), lambda i, j: (i, j)),
            pl.BlockSpec((1, ct), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rt, ct), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(idx.shape, jnp.float32),
        interpret=True,
    )(idx, m, s)


def ds_gradient_u8(idx1, idx2, m, s, x, b):
    """Double-sampling gradient straight from packed u8 level indices.

    idx1, idx2: (B, n) u8; m: (1, n) per-column scales; s: (1, 1) interval
    count; x: (n, 1); b: (B, 1). Dequantizes in-kernel, then reuses the
    tiled residual/grad kernels.
    """
    a1 = dequantize_u8(idx1, m, s)
    a2 = dequantize_u8(idx2, m, s)
    return ds_gradient(a1, a2, x, b)
