"""Stochastic quantization Pallas kernels (ZipML §2.1 / §A.3).

Three kernels:

* `stochastic_quantize` — uniform grid of ``s`` intervals over a symmetric
  per-column range ``[-m_i, m_i]`` ("column scaling", §A.3) or a shared
  scalar range ("row scaling" for model/gradient vectors). Randomness is an
  explicit uniform-[0,1) input so the lowered HLO is a pure function; the
  Rust coordinator supplies it from its own RNG.
* `stochastic_levels` — stochastic rounding onto an *arbitrary sorted level
  grid* (the variance-optimal levels of §3, computed by the Rust DP).
* `nearest_levels` — deterministic nearest-level assignment (used by the
  XNOR-style quantized-model forward pass of §3.3 under an STE backward).

TPU mapping (DESIGN.md §4): all three are elementwise over a (rows, cols)
tile; BlockSpec tiles the plane so each VMEM-resident block is quantized
in-place — the dequantize-on-the-fly half lives in `ds_grad.py`.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block tile sizes: 8x128 is the f32 VPU lane layout on TPU; interpret mode
# does not care, but we keep the shapes MXU/VPU-friendly on purpose.
_ROW_TILE = 8
_COL_TILE = 128


def _tile(dim: int, tile: int) -> int:
    """Largest divisor of ``dim`` ≤ ``tile`` — partial tiles are NaN-padded
    under interpret mode, so blocks must divide the array exactly."""
    for cand in range(min(tile, dim), 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def _quantize_kernel(v_ref, rand_ref, m_ref, s_ref, o_ref):
    """One (Rt, Ct) tile: snap v to the uniform grid stochastically."""
    v = v_ref[...]
    m = m_ref[...]  # (1, Ct) per-column scale, broadcasts over rows
    s = s_ref[0, 0]  # number of intervals (f32 scalar)
    # u in [-1, 1]; guard m == 0 columns (constant-zero features).
    safe_m = jnp.where(m > 0.0, m, 1.0)
    u = jnp.clip(v / safe_m, -1.0, 1.0)
    t = (u + 1.0) * 0.5 * s  # in [0, s]
    lo = jnp.clip(jnp.floor(t), 0.0, s - 1.0)
    p = t - lo  # P[round up]
    idx = lo + (rand_ref[...] < p).astype(v.dtype)
    q = (idx / s * 2.0 - 1.0) * m
    o_ref[...] = jnp.where(m > 0.0, q, 0.0)


def stochastic_quantize(v, rand, m, s):
    """Quantize ``v`` (R, C) onto ``s`` uniform intervals of ``[-m, m]``.

    ``m`` has shape (1, C) (column scaling; pass (1, 1)-broadcastable for row
    scaling). ``rand`` ~ U[0,1) with v's shape; ``s`` is a (1, 1) f32 array.
    Unbiased: E[out] = clip(v, -m, m).
    """
    rows, cols = v.shape
    rt, ct = _tile(rows, _ROW_TILE), _tile(cols, _COL_TILE)
    grid = (pl.cdiv(rows, rt), pl.cdiv(cols, ct))
    return pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rt, ct), lambda i, j: (i, j)),
            pl.BlockSpec((rt, ct), lambda i, j: (i, j)),
            pl.BlockSpec((1, ct), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rt, ct), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(v.shape, v.dtype),
        interpret=True,
    )(v, rand, m, s)


def _levels_kernel(v_ref, rand_ref, levels_ref, o_ref, *, stochastic: bool):
    v = v_ref[...]  # (Rt, Ct)
    levels = levels_ref[0, :]  # (L,) sorted ascending
    # Bracketing interval: idx = #levels strictly below v, clipped so that
    # [lo, hi] = [levels[idx-1], levels[idx]] brackets clip(v, levels range).
    cmp = (v[..., None] > levels[None, None, :]).astype(jnp.float32)
    idx = jnp.clip(jnp.sum(cmp, axis=-1), 1.0, levels.shape[0] - 1.0)
    idx = idx.astype(jnp.int32)
    lo = levels[idx - 1]
    hi = levels[idx]
    vc = jnp.clip(v, levels[0], levels[-1])
    if stochastic:
        width = hi - lo
        p = jnp.where(width > 0.0, (vc - lo) / jnp.where(width > 0, width, 1.0), 0.0)
        o_ref[...] = jnp.where(rand_ref[...] < p, hi, lo)
    else:
        o_ref[...] = jnp.where(vc - lo <= hi - vc, lo, hi)


def _levels_call(v, rand, levels, stochastic):
    rows, cols = v.shape
    rt, ct = _tile(rows, _ROW_TILE), _tile(cols, _COL_TILE)
    nlv = levels.shape[0]
    grid = (pl.cdiv(rows, rt), pl.cdiv(cols, ct))
    return pl.pallas_call(
        functools.partial(_levels_kernel, stochastic=stochastic),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rt, ct), lambda i, j: (i, j)),
            pl.BlockSpec((rt, ct), lambda i, j: (i, j)),
            pl.BlockSpec((1, nlv), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rt, ct), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(v.shape, v.dtype),
        interpret=True,
    )(v, rand, levels.reshape(1, -1))


def stochastic_levels(v, rand, levels):
    """Unbiased stochastic rounding of ``v`` (R, C) onto sorted ``levels`` (L,)."""
    return _levels_call(v, rand, levels, stochastic=True)


def nearest_levels(v, levels):
    """Deterministic nearest-level assignment (XNOR-style model quantizer)."""
    dummy = jnp.zeros_like(v)
    return _levels_call(v, dummy, levels, stochastic=False)
