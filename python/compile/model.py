"""L2: ZipML model step functions in JAX, calling the L1 Pallas kernels.

Every public function here is lowered once by `aot.py` to an HLO-text
artifact and executed from the Rust coordinator's hot loop — Python never
runs at training time. All functions are pure; all randomness arrives as
explicit uniform-[0,1) operands supplied by the Rust RNG.

Conventions:
  x  : model,          (n, 1) f32
  A  : sample batch,   (B, n) f32      A1/A2: independent quantizations
  b  : labels,         (B, 1) f32      (regression targets or ±1 labels)
  lr : step size,      (1, 1) f32
Losses follow Eq. (3): F(x) = 1/K Σ (aᵀx − b)² (+ R), i.e. mean squared
residual for regression models.
"""
import jax
import jax.numpy as jnp

from .kernels import (
    clenshaw,
    ds_gradient,
    ds_gradient_u8,
    nearest_levels,
    stochastic_quantize,
)

# ---------------------------------------------------------------------------
# Linear regression (§2)
# ---------------------------------------------------------------------------


def linreg_fp_step(x, a, b, lr):
    """Full-precision minibatch SGD step for least squares."""
    batch = a.shape[0]
    g = a.T @ (a @ x - b) * (1.0 / batch)
    return (x - lr * g,)


def linreg_ds_step(x, a1, a2, b, lr):
    """Double-sampling unbiased low-precision step (Eq. 6, symmetrized)."""
    g = ds_gradient(a1, a2, x, b)
    return (x - lr * g,)


def linreg_ds_u8_step(x, idx1, idx2, m, s, b, lr):
    """Double-sampling step consuming packed u8 level indices.

    Dequantization happens inside the Pallas kernel — the bandwidth-faithful
    path (1 byte/value over the host↔device link instead of 4).
    """
    g = ds_gradient_u8(idx1, idx2, m, s, x, b)
    return (x - lr * g,)


def linreg_loss(x, a, b):
    r = a @ x - b
    return (jnp.mean(r * r).reshape(1, 1),)


# ---------------------------------------------------------------------------
# Least-squares SVM (§F.1): linear regression on ±1 labels + l2 reg
# ---------------------------------------------------------------------------


def lssvm_fp_step(x, a, b, lr, c):
    batch = a.shape[0]
    g = a.T @ (a @ x - b) * (1.0 / batch) + c * x
    return (x - lr * g,)


def lssvm_ds_step(x, a1, a2, b, lr, c):
    g = ds_gradient(a1, a2, x, b) + c * x
    return (x - lr * g,)


def lssvm_loss(x, a, b, c):
    r = a @ x - b
    val = jnp.mean(r * r) + 0.5 * jnp.sum(c * x * x)
    return (val.reshape(1, 1),)


# ---------------------------------------------------------------------------
# End-to-end quantization (§E): samples + model + gradient all quantized
# ---------------------------------------------------------------------------


def e2e_step(x, a1, a2, b, lr, rand_m, rand_g, s_m, s_g):
    """g = Q4( DS-grad(a1, a2, Q3(x)) ); update applied in full precision.

    Q3 (model) and Q4 (gradient) use row scaling M = ‖v‖₂ (§A.3); a1/a2 are
    already-quantized samples (column scaling happens in the Rust store).
    rand_m/rand_g: (1, n) uniforms; s_m/s_g: (1, 1) interval counts.
    """
    n = x.shape[0]
    mx = jnp.sqrt(jnp.sum(x * x)).reshape(1, 1)
    xq = stochastic_quantize(x.reshape(1, n), rand_m, jnp.broadcast_to(mx, (1, n)), s_m)
    g = ds_gradient(a1, a2, xq.reshape(n, 1), b)
    mg = jnp.sqrt(jnp.sum(g * g)).reshape(1, 1)
    gq = stochastic_quantize(g.reshape(1, n), rand_g, jnp.broadcast_to(mg, (1, n)), s_g)
    return (x - lr * gq.reshape(n, 1),)


def quantize_v(v, rand, m, s):
    """Standalone stochastic quantizer artifact (1, n) — used by tests and
    by the coordinator for gradient/model compression outside step fusion."""
    return (stochastic_quantize(v, rand, m, s),)


# ---------------------------------------------------------------------------
# Smooth non-linear models (§4.2): logistic regression
# ---------------------------------------------------------------------------


def logistic_fp_step(x, a, b, lr):
    """Exact logistic SGD: ℓ(z)=log(1+e^{-z}), z = b·aᵀx, ℓ'(z) = -σ(-z)."""
    batch = a.shape[0]
    z = b * (a @ x)
    lp = -jax.nn.sigmoid(-z)
    g = a.T @ (b * lp) * (1.0 / batch)
    return (x - lr * g,)


def logistic_loss(x, a, b):
    z = b * (a @ x)
    return (jnp.mean(jnp.logaddexp(0.0, -z)).reshape(1, 1),)


def cheby_step(x, a1, a2, b, lr, coefs):
    """Chebyshev-approximate gradient step (practical variant, Fig 9).

    P ≈ ℓ' as Chebyshev coefficients ``coefs`` (D+1, 1) on [-R, R] with
    R = RADIUS; z is evaluated on one quantization, the gradient direction
    uses an independent one (bias ≤ ε sup-norm of the approximation).
    """
    batch = a1.shape[0]
    z = b * (a1 @ x)
    p = clenshaw(z, coefs, RADIUS)
    g = a2.T @ (b * p) * (1.0 / batch)
    return (x - lr * g,)


RADIUS = 8.0  # approximation interval [-R, R]; Rust clips ‖x‖ accordingly


def poly_ds_step(x, aq, b, lr, mono):
    """Unbiased polynomial gradient via d+1 independent quantizations (§4.1).

    aq: (d+1, B, n) — slices 0..d-1 feed the monomial products, slice d is
    the gradient direction. mono: (d+1, 1) monomial coefficients of P
    (converted from Chebyshev in the Rust coordinator, f64).
    Q(P) = Σ_i m_i Π_{j≤i} (b · Q_j(a)ᵀ x); g = E[b · Q(P) · Q_{d+1}(a)].
    """
    d_plus_1, batch, _ = aq.shape
    deg = d_plus_1 - 1
    z = b[None, :, :] * (aq[:deg] @ x)  # (d, B, 1)
    cum = jnp.cumprod(z, axis=0)  # cum[i] = Π_{j≤i} z_j
    pval = mono[0, 0] + jnp.sum(mono[1:, :, None] * cum, axis=0)  # (B, 1)
    g = aq[deg].T @ (b * pval) * (1.0 / batch)
    return (x - lr * g,)


# ---------------------------------------------------------------------------
# Non-smooth non-linear models (§4.3): SVM / hinge
# ---------------------------------------------------------------------------


def svm_fp_step(x, a, b, lr):
    """Hinge subgradient step: g = -mean(1[z<1] · b · a)."""
    batch = a.shape[0]
    z = b * (a @ x)
    mask = (z < 1.0).astype(x.dtype)
    g = -(a.T @ (b * mask)) * (1.0 / batch)
    return (x - lr * g,)


def hinge_loss(x, a, b):
    z = b * (a @ x)
    return (jnp.mean(jnp.maximum(0.0, 1.0 - z)).reshape(1, 1),)


def margins(x, a, b):
    """z = b ⊙ (A x) — the quantity the ℓ1-refetch bound (§G.4) brackets."""
    return (b * (a @ x),)


# ---------------------------------------------------------------------------
# Deep-learning extension (§3.3): MLP with quantized weights, STE backward
# ---------------------------------------------------------------------------

MLP_DIMS = (784, 256, 128, 10)


@jax.custom_vjp
def _ste_quant(w, levels):
    """Forward: nearest of `levels`; backward: identity (straight-through).

    custom_vjp keeps AD from trying to linearize through the Pallas call —
    the backward pass passes the cotangent straight through to ``w``.
    """
    return nearest_levels(w, levels)


def _ste_fwd(w, levels):
    return _ste_quant(w, levels), None


def _ste_bwd(_res, g):
    return (g, None)


_ste_quant.defvjp(_ste_fwd, _ste_bwd)


def _mlp_forward(params, x, levels=None):
    w1, b1, w2, b2, w3, b3 = params
    if levels is not None:
        l1, l2, l3 = levels
        w1 = _ste_quant(w1, l1)
        w2 = _ste_quant(w2, l2)
        w3 = _ste_quant(w3, l3)
    h = jax.nn.relu(x @ w1 + b1)
    h = jax.nn.relu(h @ w2 + b2)
    return h @ w3 + b3


def _mlp_loss(params, x, y, levels=None):
    logits = _mlp_forward(params, x, levels)
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(y, MLP_DIMS[-1], dtype=logits.dtype)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def mlp_fp_step(w1, b1, w2, b2, w3, b3, x, y, lr):
    params = (w1, b1, w2, b2, w3, b3)
    loss, grads = jax.value_and_grad(_mlp_loss)(params, x, y)
    step = lr[0, 0]
    new = tuple(p - step * g for p, g in zip(params, grads))
    return new + (loss.reshape(1, 1),)


def mlp_q_step(w1, b1, w2, b2, w3, b3, x, y, lr, l1, l2, l3):
    """Quantized-model training step: min_W l(Q(W)) with STE (§3.3).

    The level grids l1/l2/l3 are either uniform ("XNOR5") or the variance-
    optimal grids from the Rust DP ("Optimal5") — same artifact serves both.
    """
    params = (w1, b1, w2, b2, w3, b3)
    loss, grads = jax.value_and_grad(_mlp_loss)(params, x, y, (l1, l2, l3))
    step = lr[0, 0]
    new = tuple(p - step * g for p, g in zip(params, grads))
    return new + (loss.reshape(1, 1),)


def mlp_eval_fp(w1, b1, w2, b2, w3, b3, x, y):
    params = (w1, b1, w2, b2, w3, b3)
    logits = _mlp_forward(params, x)
    loss = _mlp_loss(params, x, y)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return (loss.reshape(1, 1), acc.reshape(1, 1))


def mlp_eval_q(w1, b1, w2, b2, w3, b3, x, y, l1, l2, l3):
    params = (w1, b1, w2, b2, w3, b3)
    logits = _mlp_forward(params, x, (l1, l2, l3))
    loss = _mlp_loss(params, x, y, (l1, l2, l3))
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return (loss.reshape(1, 1), acc.reshape(1, 1))


# ---------------------------------------------------------------------------
# Epoch-fused steps (perf pass): scan over pre-batched data, one dispatch
# ---------------------------------------------------------------------------


def linreg_fp_epoch(x, a_all, b_all, lr):
    """lax.scan over (nb, B, n) batches — removes per-step PJRT dispatch."""

    def body(xc, batch):
        a, b = batch
        bsz = a.shape[0]
        g = a.T @ (a @ xc - b) * (1.0 / bsz)
        return xc - lr * g, ()

    xf, _ = jax.lax.scan(body, x, (a_all, b_all))
    return (xf,)


def linreg_ds_epoch(x, a1_all, a2_all, b_all, lr):
    def body(xc, batch):
        a1, a2, b = batch
        bsz = a1.shape[0]
        r1 = a1 @ xc - b
        r2 = a2 @ xc - b
        g = (a1.T @ r2 + a2.T @ r1) * (0.5 / bsz)
        return xc - lr * g, ()

    xf, _ = jax.lax.scan(body, x, (a1_all, a2_all, b_all))
    return (xf,)
