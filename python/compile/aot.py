"""AOT compile path: lower every L2 step function to HLO *text* artifacts.

HLO text (NOT `lowered.compile()` / `.serialize()`) is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids which the
xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot [--out-dir ../artifacts] [--only REGEX]

Writes one `<name>.hlo.txt` per artifact plus `manifest.json` describing
input/output shapes so the Rust runtime can build literals without guessing.
"""
import argparse
import json
import re
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32
I32 = jnp.int32
U8 = jnp.uint8

BATCH = 64
CHEBY_DEG = 15  # degree-15 polynomial → 16 independent quantizations (§5.4)

# Shape classes. Regression ns cover Table 1 equivalents (cadata 8,
# synthetic 10, cpusmall 12, YearPrediction 90, synthetic 100/1000) plus the
# 64x64 tomography volume (n = 4096). Classification: cod-rna 8,
# synthetic 100, gisette-like 500 (scaled from 5000; DESIGN.md §3).
REGRESSION_NS = [8, 10, 12, 90, 100, 500, 1000, 4096]
CLASSIFICATION_NS = [8, 100, 500]
FIG6_BATCHES = [16, 256]  # minibatch-impact experiment, n = 100

MLP_DIMS = model.MLP_DIMS
MLP_LEVELS = 33  # max level-grid size for the quantized-model artifacts


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dtype_tag(dt) -> str:
    return {np.dtype("float32"): "f32", np.dtype("int32"): "i32", np.dtype("uint8"): "u8"}[
        np.dtype(dt)
    ]


def to_hlo_text(fn, specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def registry():
    """name -> (fn, [(arg_name, spec)], num_outputs, meta)"""
    arts = {}

    def add(name, fn, args, nout, **meta):
        assert name not in arts, name
        arts[name] = (fn, args, nout, meta)

    def linear_family(n, batch=BATCH, suffix=""):
        x = ("x", spec((n, 1)))
        a = ("a", spec((batch, n)))
        b = ("b", spec((batch, 1)))
        lr = ("lr", spec((1, 1)))
        c = ("c", spec((1, 1)))
        tag = f"_n{n}{suffix}"
        add(f"linreg_fp_step{tag}", model.linreg_fp_step, [x, a, b, lr], 1,
            kind="linreg_fp_step", n=n, batch=batch)
        add(f"linreg_ds_step{tag}", model.linreg_ds_step,
            [x, ("a1", spec((batch, n))), ("a2", spec((batch, n))), b, lr], 1,
            kind="linreg_ds_step", n=n, batch=batch)
        add(f"linreg_loss{tag}", model.linreg_loss, [x, a, b], 1,
            kind="linreg_loss", n=n, batch=batch)
        if suffix:
            return
        add(f"linreg_ds_u8_step{tag}", model.linreg_ds_u8_step,
            [x, ("idx1", spec((batch, n), U8)), ("idx2", spec((batch, n), U8)),
             ("m", spec((1, n))), ("s", spec((1, 1))), b, lr], 1,
            kind="linreg_ds_u8_step", n=n, batch=batch)
        add(f"e2e_step{tag}", model.e2e_step,
            [x, ("a1", spec((batch, n))), ("a2", spec((batch, n))), b, lr,
             ("rand_m", spec((1, n))), ("rand_g", spec((1, n))),
             ("s_m", spec((1, 1))), ("s_g", spec((1, 1)))], 1,
            kind="e2e_step", n=n, batch=batch)
        add(f"lssvm_fp_step{tag}", model.lssvm_fp_step, [x, a, b, lr, c], 1,
            kind="lssvm_fp_step", n=n, batch=batch)
        add(f"lssvm_ds_step{tag}", model.lssvm_ds_step,
            [x, ("a1", spec((batch, n))), ("a2", spec((batch, n))), b, lr, c], 1,
            kind="lssvm_ds_step", n=n, batch=batch)
        add(f"lssvm_loss{tag}", model.lssvm_loss, [x, a, b, c], 1,
            kind="lssvm_loss", n=n, batch=batch)

    def classification_family(n, batch=BATCH):
        x = ("x", spec((n, 1)))
        a = ("a", spec((batch, n)))
        b = ("b", spec((batch, 1)))
        lr = ("lr", spec((1, 1)))
        coefs = ("coefs", spec((CHEBY_DEG + 1, 1)))
        mono = ("mono", spec((CHEBY_DEG + 1, 1)))
        aq = ("aq", spec((CHEBY_DEG + 1, batch, n)))
        tag = f"_n{n}"
        add(f"logistic_fp_step{tag}", model.logistic_fp_step, [x, a, b, lr], 1,
            kind="logistic_fp_step", n=n, batch=batch)
        add(f"logistic_loss{tag}", model.logistic_loss, [x, a, b], 1,
            kind="logistic_loss", n=n, batch=batch)
        add(f"svm_fp_step{tag}", model.svm_fp_step, [x, a, b, lr], 1,
            kind="svm_fp_step", n=n, batch=batch)
        add(f"hinge_loss{tag}", model.hinge_loss, [x, a, b], 1,
            kind="hinge_loss", n=n, batch=batch)
        add(f"margins{tag}", model.margins, [x, a, b], 1,
            kind="margins", n=n, batch=batch)
        add(f"cheby_step{tag}", model.cheby_step,
            [x, ("a1", spec((batch, n))), ("a2", spec((batch, n))), b, lr, coefs], 1,
            kind="cheby_step", n=n, batch=batch, degree=CHEBY_DEG,
            radius=model.RADIUS)
        add(f"poly_ds_step{tag}", model.poly_ds_step, [x, aq, b, lr, mono], 1,
            kind="poly_ds_step", n=n, batch=batch, degree=CHEBY_DEG)

    for n in REGRESSION_NS:
        linear_family(n)
    for batch in FIG6_BATCHES:
        linear_family(100, batch=batch, suffix=f"_b{batch}")
    for n in CLASSIFICATION_NS:
        classification_family(n)

    # Standalone quantizer (tests + gradient/model compression paths).
    for n in (100, 1000):
        add(f"quantize_v_n{n}", model.quantize_v,
            [("v", spec((1, n))), ("rand", spec((1, n))),
             ("m", spec((1, n))), ("s", spec((1, 1)))], 1,
            kind="quantize_v", n=n, batch=1)

    # Epoch-fused perf variants (DESIGN.md §8): 64 batches per dispatch.
    nb, n = 64, 100
    add("linreg_fp_epoch_n100", model.linreg_fp_epoch,
        [("x", spec((n, 1))), ("a_all", spec((nb, BATCH, n))),
         ("b_all", spec((nb, BATCH, 1))), ("lr", spec((1, 1)))], 1,
        kind="linreg_fp_epoch", n=n, batch=BATCH, num_batches=nb)
    add("linreg_ds_epoch_n100", model.linreg_ds_epoch,
        [("x", spec((n, 1))), ("a1_all", spec((nb, BATCH, n))),
         ("a2_all", spec((nb, BATCH, n))), ("b_all", spec((nb, BATCH, 1))),
         ("lr", spec((1, 1)))], 1,
        kind="linreg_ds_epoch", n=n, batch=BATCH, num_batches=nb)

    # Deep-learning extension (§3.3).
    d0, d1, d2, d3 = MLP_DIMS
    params = [("w1", spec((d0, d1))), ("b1", spec((1, d1))),
              ("w2", spec((d1, d2))), ("b2", spec((1, d2))),
              ("w3", spec((d2, d3))), ("b3", spec((1, d3)))]
    xy = [("x", spec((BATCH, d0))), ("y", spec((BATCH,), I32))]
    lrs = [("lr", spec((1, 1)))]
    lvls = [("l1", spec((MLP_LEVELS,))), ("l2", spec((MLP_LEVELS,))),
            ("l3", spec((MLP_LEVELS,)))]
    add("mlp_fp_step", model.mlp_fp_step, params + xy + lrs, 7,
        kind="mlp_fp_step", batch=BATCH, dims=list(MLP_DIMS))
    add("mlp_q_step", model.mlp_q_step, params + xy + lrs + lvls, 7,
        kind="mlp_q_step", batch=BATCH, dims=list(MLP_DIMS), levels=MLP_LEVELS)
    add("mlp_eval_fp", model.mlp_eval_fp, params + xy, 2,
        kind="mlp_eval_fp", batch=BATCH, dims=list(MLP_DIMS))
    add("mlp_eval_q", model.mlp_eval_q, params + xy + lvls, 2,
        kind="mlp_eval_q", batch=BATCH, dims=list(MLP_DIMS), levels=MLP_LEVELS)

    return arts


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=str(Path(__file__).resolve().parents[2] / "artifacts"))
    ap.add_argument("--only", default=None, help="regex filter on artifact names")
    args = ap.parse_args()

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    arts = registry()
    pattern = re.compile(args.only) if args.only else None

    manifest = {"batch": BATCH, "cheby_degree": CHEBY_DEG, "radius": model.RADIUS,
                "mlp_dims": list(MLP_DIMS), "mlp_levels": MLP_LEVELS, "artifacts": {}}
    t0 = time.time()
    for name, (fn, named_specs, nout, meta) in sorted(arts.items()):
        if pattern and not pattern.search(name):
            continue
        t1 = time.time()
        specs = [s for (_, s) in named_specs]
        text = to_hlo_text(fn, specs)
        (out_dir / f"{name}.hlo.txt").write_text(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"name": an, "shape": list(s.shape), "dtype": _dtype_tag(s.dtype)}
                for (an, s) in named_specs
            ],
            "num_outputs": nout,
            "meta": meta,
        }
        print(f"  lowered {name:36s} {time.time() - t1:6.2f}s  {len(text) // 1024:5d} KiB")
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    # Line-based twin for the Rust loader (no serde in the offline crate set):
    #   artifact\t<name>\t<file>\t<num_outputs>
    #   input\t<name>\t<arg>\t<dtype>\t<d0,d1,...>
    #   meta\t<name>\t<key>\t<value>
    lines = []
    for name, entry in sorted(manifest["artifacts"].items()):
        lines.append(f"artifact\t{name}\t{entry['file']}\t{entry['num_outputs']}")
        for i in entry["inputs"]:
            dims = ",".join(str(d) for d in i["shape"])
            lines.append(f"input\t{name}\t{i['name']}\t{i['dtype']}\t{dims}")
        for k, v in entry["meta"].items():
            lines.append(f"meta\t{name}\t{k}\t{v}")
    (out_dir / "manifest.tsv").write_text("\n".join(lines) + "\n")
    print(f"wrote {len(manifest['artifacts'])} artifacts to {out_dir} "
          f"in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
